"""CLI: ``python -m repro.analysis [--strict] [--json] [--pass NAME]...``

Exit status: 0 when no error-severity findings survive (or without
``--strict``, always 0 unless a pass crashes); 1 when ``--strict`` and
errors remain.  ``--inventory [PATH]`` writes the import-graph dead-code
census (defaults to ``ANALYSIS_inventory.json`` at the repo root) and
prints its summary.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

# static imports (not just run_all's lazy ones) so the import-graph
# inventory sees every pass module as reachable from this entry point
from repro.analysis import PASSES, run_all
from repro.analysis import findings as _findings  # noqa: F401
from repro.analysis import fuzz as _fuzz  # noqa: F401
from repro.analysis import inventory as inventory_mod
from repro.analysis import lint as _lint  # noqa: F401
from repro.analysis import locks as _locks  # noqa: F401
from repro.analysis import spmd_audit as _spmd  # noqa: F401


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="SPMD auditor, serve-tier linter, and lock checker",
    )
    ap.add_argument(
        "--pass", dest="passes", action="append", choices=PASSES,
        help="run only the named pass(es); default: all",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 if any error-severity finding remains (the CI gate)",
    )
    ap.add_argument(
        "--quick", action="store_true",
        help="subset the SPMD geometry sweep (smoke runs, not the gate)",
    )
    ap.add_argument("--json", action="store_true", help="emit the report as JSON")
    ap.add_argument(
        "--inventory", nargs="?", const="ANALYSIS_inventory.json",
        metavar="PATH", default=None,
        help="write the import-graph dead-code census and exit",
    )
    ap.add_argument(
        "--root", default=None,
        help="repo root override (default: inferred from the package path)",
    )
    args = ap.parse_args(argv)

    if args.inventory is not None:
        root = (
            pathlib.Path(args.root)
            if args.root
            else inventory_mod._repo_root()
        )
        out = root / args.inventory
        inv = inventory_mod.write_inventory(out, root=root)
        print(
            f"{out}: {inv['n_modules']} modules — {inv['n_reachable']} "
            f"reachable, {inv['n_seed_tier']} seed-tier, "
            f"{inv['n_test_only']} test-only, {len(inv['dead'])} dead "
            f"({inv['loc_dead']} LoC)"
        )
        for d in inv["dead"]:
            print(f"  dead: {d['module']} ({d['loc']} LoC, {d['defs']} defs)")
        return 0

    report = run_all(
        tuple(args.passes) if args.passes else PASSES,
        quick=args.quick,
        root=args.root,
    )
    print(report.to_json() if args.json else report.format())
    if args.strict and not report.ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
