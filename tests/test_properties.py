"""Property-based tests (hypothesis): closure axioms, partition theorems,
lectic order — the system's invariants from the paper's §2–3."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

from repro.core import bitset, closure, lectic
from repro.core.context import FormalContext

settings.register_profile("repro", deadline=None, max_examples=30)
settings.load_profile("repro")


@st.composite
def contexts(draw, max_objects=60, max_attrs=40):
    n = draw(st.integers(1, max_objects))
    m = draw(st.integers(1, max_attrs))
    density = draw(st.floats(0.05, 0.7))
    seed = draw(st.integers(0, 2**31 - 1))
    return FormalContext.synthetic(n, m, density, seed=seed)


@st.composite
def context_and_attrset(draw):
    ctx = draw(contexts())
    bits = draw(st.lists(st.integers(0, ctx.n_attrs - 1), max_size=8))
    return ctx, bitset.from_indices(set(bits), ctx.n_attrs)


@given(context_and_attrset())
def test_closure_extensive_monotone_idempotent(args):
    ctx, Y = args
    mask = ctx.attr_mask()
    c1, _ = closure.closure_np(ctx.rows, Y, mask)
    # extensive: Y ⊆ Y''
    assert not np.any(Y & ~c1)
    # idempotent: (Y'')'' == Y''
    c2, _ = closure.closure_np(ctx.rows, c1, mask)
    assert np.array_equal(c1, c2)


@given(context_and_attrset(), context_and_attrset())
def test_closure_monotone(a, b):
    ctx, Y1 = a
    _, _ = b
    # build Y2 ⊇ Y1 within the same context
    extra = bitset.from_indices({0}, ctx.n_attrs)
    Y2 = Y1 | extra
    mask = ctx.attr_mask()
    c1, _ = closure.closure_np(ctx.rows, Y1, mask)
    c2, _ = closure.closure_np(ctx.rows, Y2, mask)
    assert not np.any(c1 & ~c2)  # Y1 ⊆ Y2 ⇒ Y1'' ⊆ Y2''


@given(context_and_attrset(), st.integers(2, 5), st.booleans())
def test_property1_extent_union(args, n_parts, shuffle):
    """Y'_S = ∪_k Y'_{S_k} (object partitioning preserves extents)."""
    ctx, Y = args
    parts = ctx.partition(min(n_parts, ctx.n_objects), shuffle=shuffle, seed=7)
    whole = closure.extent_np(ctx.rows, Y)
    got = sum(int(closure.extent_np(p.rows, Y).sum()) for p in parts)
    assert got == int(whole.sum())


@given(context_and_attrset(), st.integers(2, 5))
def test_theorem2_closure_intersection(args, n_parts):
    """Y''_S = ∩_k Y''_{S_k} (the paper's Theorem 2, n-way)."""
    ctx, Y = args
    k = min(n_parts, ctx.n_objects)
    parts = ctx.partition(k)
    mask = ctx.attr_mask()
    whole, _ = closure.closure_np(ctx.rows, Y, mask)
    acc = mask.copy()
    for p in parts:
        c, _ = closure.closure_np(p.rows, Y, mask)
        acc &= c
    assert np.array_equal(acc, whole)


@given(contexts(max_objects=20, max_attrs=10))
def test_lectic_order_is_total_on_subsets(ctx):
    m = min(ctx.n_attrs, 6)
    rows = [bitset.from_indices(
        {a for a in range(m) if (i >> a) & 1}, ctx.n_attrs
    ) for i in range(2 ** m)]
    keys = [lectic.lectic_sort_key(r, ctx.n_attrs) for r in rows]
    order = np.argsort(np.array([int("".join(map(str, k)).ljust(1, "0"), 2)
                                 if k else 0 for k in keys]))
    # pairwise consistency of lectic_leq with the sort keys
    for i in range(0, len(rows) - 1, 7):
        a, b = rows[i], rows[i + 1]
        if np.array_equal(a, b):
            continue
        assert lectic.lectic_leq(a, b, ctx.n_attrs) == (keys[i] < keys[i + 1])


@given(context_and_attrset())
def test_oplus_seeds_match_scalar(args):
    ctx, Y = args
    tables = lectic.LecticTables(ctx.n_attrs)
    seeds, valid = lectic.oplus_seeds_all(Y, tables)
    member = bitset.unpack_bits(Y, ctx.n_attrs)
    for a in range(ctx.n_attrs):
        assert valid[a] == (not member[a])
        if valid[a]:
            assert np.array_equal(seeds[a], lectic.oplus_seed(Y, a, tables))


@given(contexts(max_objects=40, max_attrs=16))
def test_batched_closure_matches_scalar(ctx):
    rng = np.random.default_rng(0)
    B = 9
    cands = bitset.pack_bool(rng.random((B, ctx.n_attrs)) < 0.2)
    mask = ctx.attr_mask()
    bc, bs = closure.batched_closure_np(ctx.rows, cands, mask)
    for i in range(B):
        c, s = closure.closure_np(ctx.rows, cands[i], mask)
        assert np.array_equal(bc[i], c) and bs[i] == s
