"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].  O(1)-state decode → long_500k eligible."""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,  # d_inner / head_dim = 2048/64 (bookkeeping only; attn-free)
    n_kv_heads=1,
    d_ff=0,  # no FFN sub-layer in mamba2 blocks
    vocab_size=50_280,
    rope_kind="none",
    layer_pattern=("ssd",),
    ssm=SSMConfig(state_size=128, conv_width=4, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    subquadratic=True,
)
