"""The MR* miners: MRGanter, MRGanter+ and MRCbo (paper §3), as host-side
iterative drivers over a :class:`repro.core.engine.ClosureEngine`.

Each driver is the Twister control loop: the engine holds the static data
(sharded context); the *dynamic data* — the previous intent(s) — crosses the
host/device boundary once per iteration, exactly like Twister re-configuring
its long-running map tasks with the previous iteration's closures.

Iteration counts follow the paper's convention (Table 9): every map/reduce
round over the full context counts as one iteration, including the round
that computes ``∅''`` and, for MRGanter+/MRCbo, the final round that proves
the frontier is exhausted.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import bitset, lectic
from repro.core.engine import ClosureEngine
from repro.core.hashindex import TwoLevelHash


@dataclasses.dataclass
class MRResult:
    intents: list[np.ndarray]
    n_iterations: int
    n_closures_computed: int
    modeled_comm_bytes: int
    wall_time_s: float
    algorithm: str

    @property
    def n_concepts(self) -> int:
        return len(self.intents)


def _seeds_for(Y: np.ndarray, tables: lectic.LecticTables) -> np.ndarray:
    seeds, valid = lectic.oplus_seeds_all(Y, tables)
    return seeds[valid]


# ---------------------------------------------------------------------------
# MRGanter (Algorithms 4 + 5): strict lectic order, one concept/iteration.
# ---------------------------------------------------------------------------


def mrganter(
    ctx, engine: ClosureEngine, max_iterations: int | None = None
) -> MRResult:
    t0 = time.perf_counter()
    tables = lectic.LecticTables(ctx.n_attrs)
    full = ctx.attr_mask()
    Y, _ = engine.first_closure()
    intents = [Y]
    n_iter = 1
    while not np.array_equal(Y, full):
        if max_iterations is not None and n_iter >= max_iterations:
            break
        # Map: local closures for every attribute p_i ∉ d (Alg. 4).
        seeds, valid = lectic.oplus_seeds_all(Y, tables)
        closures, _ = engine.closure(seeds)  # Reduce: Theorem-2 intersection
        # Feasibility ≤_{p_i} (Alg. 5): first success scanning p_m → p_1.
        ok = lectic.feasible_batch(closures, Y, tables) & valid
        idx = np.nonzero(ok)[0]
        assert idx.size, "NextClosure invariant: a feasible successor exists"
        Y = closures[int(idx.max())]
        intents.append(Y)
        n_iter += 1
    return MRResult(
        intents=intents,
        n_iterations=n_iter,
        n_closures_computed=engine.stats.closures_computed,
        modeled_comm_bytes=engine.stats.modeled_comm_bytes,
        wall_time_s=time.perf_counter() - t0,
        algorithm="mrganter",
    )


# ---------------------------------------------------------------------------
# MRGanter+ (Algorithms 4 + 6): keep all new closures, dedupe via the
# two-level hash; iterations collapse to ~lattice depth.
# ---------------------------------------------------------------------------


def mrganter_plus(
    ctx,
    engine: ClosureEngine,
    *,
    dedupe_candidates: bool = False,
    max_iterations: int | None = None,
) -> MRResult:
    """``dedupe_candidates=False`` is the paper-faithful map phase (every
    frontier intent emits a candidate for every absent attribute).  ``True``
    additionally drops duplicate *seeds* before the closure — a beyond-paper
    optimization benchmarked in EXPERIMENTS.md (same output, fewer closures).
    """
    t0 = time.perf_counter()
    tables = lectic.LecticTables(ctx.n_attrs)
    H = TwoLevelHash()
    Y0, _ = engine.first_closure()
    H.add(Y0)
    intents = [Y0]
    frontier = [Y0]
    n_iter = 1
    while frontier:
        if max_iterations is not None and n_iter >= max_iterations:
            break
        seed_list = [_seeds_for(Y, tables) for Y in frontier]
        seeds = (
            np.concatenate(seed_list, axis=0)
            if seed_list
            else np.zeros((0, ctx.W), np.uint32)
        )
        if seeds.shape[0] == 0:
            break
        if dedupe_candidates:
            seeds = np.unique(seeds, axis=0)
        n_iter += 1
        closures, _ = engine.closure(seeds)
        new_idx = H.add_batch(closures)
        frontier = [closures[i] for i in new_idx]
        intents.extend(frontier)
    return MRResult(
        intents=intents,
        n_iterations=n_iter,
        n_closures_computed=engine.stats.closures_computed,
        modeled_comm_bytes=engine.stats.modeled_comm_bytes,
        wall_time_s=time.perf_counter() - t0,
        algorithm="mrganter+",
    )


# ---------------------------------------------------------------------------
# MRCbo: distributed CloseByOne under the same engine (paper §5 baseline).
# ---------------------------------------------------------------------------


def mrcbo(
    ctx, engine: ClosureEngine, max_iterations: int | None = None
) -> MRResult:
    t0 = time.perf_counter()
    tables = lectic.LecticTables(ctx.n_attrs)
    root, _ = engine.first_closure()
    intents = [root]
    frontier: list[tuple[np.ndarray, int]] = [(root, -1)]
    n_iter = 1
    while frontier:
        if max_iterations is not None and n_iter >= max_iterations:
            break
        seeds, parents, gens = [], [], []
        for Y, g in frontier:
            member = bitset.unpack_bits(Y, ctx.n_attrs)
            for a in range(g + 1, ctx.n_attrs):
                if not member[a]:
                    seeds.append(Y | tables.BIT[a])
                    parents.append(Y)
                    gens.append(a)
        if not seeds:
            break
        n_iter += 1
        closures, _ = engine.closure(np.stack(seeds))
        next_frontier = []
        for i in range(closures.shape[0]):
            a, Y, Z = gens[i], parents[i], closures[i]
            if np.all(((Z ^ Y) & tables.LOW[a]) == 0):  # CbO canonicity
                intents.append(Z)
                next_frontier.append((Z, a))
        frontier = next_frontier
    return MRResult(
        intents=intents,
        n_iterations=n_iter,
        n_closures_computed=engine.stats.closures_computed,
        modeled_comm_bytes=engine.stats.modeled_comm_bytes,
        wall_time_s=time.perf_counter() - t0,
        algorithm="mrcbo",
    )
