"""Device-resident frontier pipeline ≡ host-loop drivers (the tentpole's
equivalence contract): identical concept sets on the paper datasets and on
randomized contexts, across backends, partition counts and dedupe modes."""

import numpy as np
import pytest

from repro.core import (
    ClosureEngine,
    all_closures_batched,
    bitset,
    mrcbo,
    mrganter,
    mrganter_plus,
)
from repro.core.context import FormalContext
from repro.data import fca_datasets

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic seeded fallback (repro.testing)
    from repro.testing import given, settings, st

settings.register_profile("frontier", deadline=None, max_examples=12)
settings.load_profile("frontier")


def _sorted_intents(intents):
    """Canonical comparison form: lexicographically sorted packed intents."""
    arr = np.stack([np.asarray(y, dtype=np.uint32) for y in intents])
    view = arr.view([("", np.uint8)] * arr.dtype.itemsize * arr.shape[1])
    return arr[np.argsort(view, axis=0)[:, 0]]


def _assert_equiv(ctx, algo, *, n_parts=3, backend="jnp", **kw):
    eh = ClosureEngine(ctx, n_parts=n_parts, block_n=64, backend=backend)
    ed = ClosureEngine(ctx, n_parts=n_parts, block_n=64, backend=backend)
    rh = algo(ctx, eh, pipeline="host", **kw)
    rd = algo(ctx, ed, pipeline="device", **kw)
    np.testing.assert_array_equal(
        _sorted_intents(rh.intents), _sorted_intents(rd.intents)
    )
    assert rh.n_iterations == rd.n_iterations
    assert rh.n_concepts == rd.n_concepts
    return rh, rd


# -- paper datasets (Table 7, scaled for the CPU budget) ---------------------


@pytest.fixture(scope="module", params=["mushroom", "anon-web", "census-income"])
def paper_ctx(request):
    scale = {"mushroom": 0.004, "anon-web": 0.002, "census-income": 0.0006}
    ctx, _ = fca_datasets.load(request.param, scale=scale[request.param], seed=1)
    return ctx


def test_mrganter_plus_device_matches_host_on_paper_datasets(paper_ctx):
    rh, _ = _assert_equiv(paper_ctx, mrganter_plus)
    # and both match the centralized oracle
    ref = _sorted_intents(all_closures_batched(paper_ctx))
    np.testing.assert_array_equal(_sorted_intents(rh.intents), ref)


def test_mrcbo_device_matches_host_on_paper_datasets(paper_ctx):
    _assert_equiv(paper_ctx, mrcbo)


def test_mrganter_device_matches_host_on_paper_datasets(paper_ctx):
    # strict lectic order must be preserved element-for-element
    eh = ClosureEngine(paper_ctx, n_parts=2, block_n=64, backend="jnp")
    ed = ClosureEngine(paper_ctx, n_parts=2, block_n=64, backend="jnp")
    rh = mrganter(paper_ctx, eh, max_iterations=40, pipeline="host")
    rd = mrganter(paper_ctx, ed, max_iterations=40, pipeline="device")
    assert len(rh.intents) == len(rd.intents)
    for a, b in zip(rh.intents, rd.intents):
        np.testing.assert_array_equal(a, b)


# -- dedupe modes and backends ----------------------------------------------


@pytest.mark.parametrize("dedupe_candidates", [False, True])
@pytest.mark.parametrize("dedupe_closures", [False, True])
def test_mrganter_plus_dedupe_modes(dedupe_candidates, dedupe_closures):
    ctx = FormalContext.synthetic(90, 21, 0.25, seed=4)
    _assert_equiv(
        ctx, mrganter_plus,
        dedupe_candidates=dedupe_candidates, dedupe_closures=dedupe_closures,
    )


@pytest.mark.parametrize("backend", ["kernel", "jnp", "matmul"])
def test_device_pipeline_across_backends(backend):
    ctx = FormalContext.synthetic(70, 18, 0.3, seed=9)
    ref = _sorted_intents(all_closures_batched(ctx))
    eng = ClosureEngine(ctx, n_parts=2, block_n=64, backend=backend)
    res = mrganter_plus(ctx, eng, pipeline="device", dedupe_candidates=True)
    np.testing.assert_array_equal(_sorted_intents(res.intents), ref)


def test_engine_rejects_unknown_backend():
    ctx = FormalContext.synthetic(10, 6, 0.4, seed=0)
    with pytest.raises(ValueError, match="backend"):
        ClosureEngine(ctx, n_parts=1, backend="tpu9000")


def test_driver_rejects_unknown_pipeline():
    ctx = FormalContext.synthetic(10, 6, 0.4, seed=0)
    eng = ClosureEngine(ctx, n_parts=1, backend="jnp")
    with pytest.raises(ValueError, match="pipeline"):
        mrganter_plus(ctx, eng, pipeline="quantum")


# -- transfer accounting: the pipeline's raison d'être -----------------------


def test_device_pipeline_uploads_less_than_host():
    ctx = FormalContext.synthetic(150, 24, 0.2, seed=3)
    eh = ClosureEngine(ctx, n_parts=2, block_n=64, backend="jnp")
    ed = ClosureEngine(ctx, n_parts=2, block_n=64, backend="jnp")
    mrganter_plus(ctx, eh, pipeline="host", dedupe_candidates=True)
    mrganter_plus(ctx, ed, pipeline="device", dedupe_candidates=True)
    # host ships every seed batch up; device ships only novel intents —
    # same O(1) bulk ops per round, a fraction of the bytes
    assert ed.stats.h2d_bytes * 4 < eh.stats.h2d_bytes
    assert ed.stats.h2d_transfers <= ed.stats.rounds + 1
    assert ed.stats.d2h_bytes < eh.stats.d2h_bytes


# -- randomized property sweep ----------------------------------------------


@given(
    st.integers(8, 60), st.integers(3, 22), st.floats(0.1, 0.6),
    st.integers(0, 10_000), st.integers(1, 4), st.booleans(),
)
def test_property_device_equals_host(n, m, density, seed, n_parts, dedupe):
    ctx = FormalContext.synthetic(n, m, density, seed=seed)
    _assert_equiv(
        ctx, mrganter_plus, n_parts=n_parts, dedupe_candidates=dedupe
    )
    _assert_equiv(ctx, mrcbo, n_parts=n_parts)
