"""Pallas TPU kernel for batched bitset closure — the paper's ⊕ hot-spot.

The ⊕-operation (Eqn. 5) is dominated by the closure ``Y''``: find every
object row containing the candidate attribute set, then intersect those rows.
For a candidate batch ``C [B, W]`` against context rows ``R [N, W]`` (uint32
bitset words, 32 attributes/word) the kernel computes

    match[b, n]   = all_w((R[n, w] & C[b, w]) == C[b, w])
    closure[b, w] = AND_{n : match[b, n]} R[n, w]      (identity: 0xFFFFFFFF)
    support[b]    = sum_n match[b, n]

This is an AND-accumulate "matmul" of shape (B×N×W) — VPU work, not MXU —
so the tiling goal is lane occupancy and VMEM residency, not MXU alignment:

  * Grid is (B/B_BLK, N/N_BLK) with N as the **last (fastest) axis**, so the
    output block for a given b-block is revisited across consecutive grid
    steps and can be accumulated in place (TPU sequential-grid semantics;
    ``dimension_semantics=("parallel", "arbitrary")``).
  * ``W`` stays un-gridded and VMEM-resident: one block covers up to
    ``MAX_W = 512`` words = 16 384 attributes (the paper's datasets need
    ≤ 10 words).  Wider contexts take the pure-jnp fallback in ``ops.py``.
  * VMEM per step ≈ R-block (N_BLK·W·4) + C-block (B_BLK·W·4) + the fused
    [B_BLK, N_BLK, W] intermediates ≈ 1–3 MB at the default
    (B_BLK=8, N_BLK=256, W≤128) — comfortably inside v5e VMEM.
  * The AND-reduction over N_BLK uses a log₂ tree of full-width vector ANDs
    (no scalar loop), and the W-axis ``all`` is a lane reduction.

Padding discipline (enforced by ``ops.py``):
  * object rows are padded to N_BLK multiples with **all-ones** rows — the
    AND identity; they match every candidate, so supports are corrected by
    the constant pad count outside the kernel;
  * candidate rows are padded with all-ones and their outputs dropped;
  * attribute words are zero-padded; the final closure is masked with
    ``attr_mask`` outside the kernel.

dtype note: the kernel operates on uint32 words; on TPU Mosaic these lower
as 32-bit integer lanes (bitwise ops are dtype-width agnostic).  The kernel
is validated in ``interpret=True`` mode against ``ref.py`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from jax.experimental.pallas import tpu as pltpu

DEFAULT_B_BLK = 8
DEFAULT_N_BLK = 256
MAX_W = 512
FULL_WORD = 0xFFFFFFFF  # python int — becomes an in-kernel literal


def _tree_and(x: jax.Array, axis: int) -> jax.Array:
    """Bitwise-AND reduce along ``axis`` via a log2 tree (static shapes)."""
    x = jnp.moveaxis(x, axis, 0)
    n = x.shape[0]
    while n > 1:
        half = n // 2
        paired = x[: 2 * half]
        x = jnp.concatenate([paired[0::2] & paired[1::2], x[2 * half :]], axis=0)
        n = x.shape[0]
    return x[0]


def _closure_kernel(cand_ref, rows_ref, out_c_ref, out_s_ref):
    n_step = pl.program_id(1)
    cands = cand_ref[...]  # [B_BLK, W] uint32
    rows = rows_ref[...]  # [N_BLK, W] uint32

    # match[b, n] ⟺ candidate b ⊆ row n  (word-parallel subset test).
    inter = rows[None, :, :] & cands[:, None, :]  # [B_BLK, N_BLK, W]
    match = jnp.all(inter == cands[:, None, :], axis=-1)  # [B_BLK, N_BLK]

    # AND of matching rows; non-matching rows contribute the AND identity.
    full = jnp.full((), FULL_WORD, dtype=jnp.uint32)
    sel = jnp.where(match[:, :, None], rows[None, :, :], full)
    acc = _tree_and(sel, axis=1)  # [B_BLK, W]
    sup = jnp.sum(match.astype(jnp.int32), axis=-1, keepdims=True)  # [B_BLK, 1]

    @pl.when(n_step == 0)
    def _init():
        out_c_ref[...] = acc
        out_s_ref[...] = sup

    @pl.when(n_step != 0)
    def _accum():
        out_c_ref[...] = out_c_ref[...] & acc
        out_s_ref[...] = out_s_ref[...] + sup


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret")
)
def closure_pallas(
    rows: jax.Array,
    cands: jax.Array,
    *,
    block_b: int = DEFAULT_B_BLK,
    block_n: int = DEFAULT_N_BLK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel invocation.  Shapes must already be block-aligned.

    rows  [N, W] uint32, N % block_n == 0, rows padded with all-ones.
    cands [B, W] uint32, B % block_b == 0.
    Returns (closures [B, W] — unmasked, supports [B] int32 — uncorrected).
    """
    N, W = rows.shape
    B, Wc = cands.shape
    if W != Wc:
        raise ValueError(f"word-width mismatch rows W={W} cands W={Wc}")
    if W > MAX_W:
        raise ValueError(f"W={W} exceeds kernel MAX_W={MAX_W}; use jnp fallback")
    if N % block_n or B % block_b:
        raise ValueError(f"unaligned shapes N={N}%{block_n}, B={B}%{block_b}")

    grid = (B // block_b, N // block_n)
    out_c, out_s = pl.pallas_call(
        _closure_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, W), lambda b, n: (b, 0)),
            pl.BlockSpec((block_n, W), lambda b, n: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, W), lambda b, n: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, n: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, W), jnp.uint32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(cands, rows)
    return out_c, out_s[:, 0]
