"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

Backbone-only per assignment rules: the EnCodec tokenizer/codebook-interleave
frontend is a stub — ``input_specs()`` provides precomputed frame embeddings
[B, S, d] (sum of per-codebook embeddings + sinusoidal positions); the head
predicts one 2048-way codebook stream.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,  # MHA
    d_ff=8192,
    vocab_size=2048,  # EnCodec codebook size
    rope_kind="none",  # sinusoidal positions live in the stubbed embeddings
    mlp_kind="gelu",
    input_mode="embeds",
)
