"""Pallas closure-kernel micro-bench (interpret mode on CPU) vs oracles.

Wall times here are *not* TPU projections (interpret mode runs the kernel
body in Python/XLA-CPU); the point is the work-per-call census used in the
§Roofline discussion plus regression tracking of the jnp reference path.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import FormalContext
from repro.core.closure import batched_closure_np
from repro.kernels import ops


def run(shapes=((2048, 128, 256), (8192, 512, 64))) -> list[str]:
    out = []
    for N, m, B in shapes:
        ctx = FormalContext.synthetic(N, m, 0.15, seed=1)
        cands = FormalContext.synthetic(B, m, 0.05, seed=2).rows
        rows_p, _ = ctx.padded_rows(256)
        rows_j, cands_j = jnp.asarray(rows_p), jnp.asarray(cands)

        # warm + time the jnp reference path (jit, no pallas)
        f_ref = lambda: ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, use_kernel=False
        )[0].block_until_ready()
        f_ref()
        _, t_ref = timed(f_ref)

        # numpy oracle
        _, t_np = timed(batched_closure_np, ctx.rows, cands, ctx.attr_mask())

        # pallas interpret (correctness-path cost only)
        f_k = lambda: ops.batched_closure(
            rows_j, cands_j, m, n_valid_rows=N, use_kernel=True
        )[0].block_until_ready()
        f_k()
        _, t_k = timed(f_k)

        work = B * N * ops.bucket_size(1)  # word-ops order of magnitude
        out.append(row(
            f"kernel/closure/N={N},m={m},B={B}/jnp_ref", 1e6 * t_ref,
            f"numpy_us={1e6 * t_np:.0f}|pallas_interpret_us={1e6 * t_k:.0f}"
            f"|BNW={B * N * (m // 32 + 1)}",
        ))
    return out
