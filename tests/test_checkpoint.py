"""Checkpointing: roundtrip, atomicity, integrity, keep-k, async."""

import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)},
        "scalar": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    restored = restore_checkpoint(str(tmp_path), 3, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402  (used above via jax.tree_leaves)


def test_latest_skips_uncommitted(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    # simulate a crash mid-save: step 3 exists without COMMITTED
    d = tmp_path / "step_00000003"
    shutil.copytree(tmp_path / "step_00000002", d)
    os.remove(d / "COMMITTED")
    assert latest_step(str(tmp_path)) == 2


def test_checksum_detects_corruption_any_codec(tmp_path):
    """Codec-independent integrity check: flip one byte of a leaf payload
    (re-compressing when the codec is zstd) and expect a checksum error."""
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    d = tmp_path / "step_00000001"
    target = sorted(p for p in os.listdir(d) if p.startswith("leaf_"))[0]
    with open(d / target, "rb") as f:
        payload = f.read()
    if target.endswith(".zst"):
        import zstandard

        data = bytearray(zstandard.ZstdDecompressor().decompress(payload))
        data[0] ^= 0xFF
        payload = zstandard.ZstdCompressor().compress(bytes(data))
    else:
        data = bytearray(payload)
        data[0] ^= 0xFF
        payload = bytes(data)
    with open(d / target, "wb") as f:
        f.write(payload)
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), 1, t)


def test_structure_mismatch_rejected(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    bad = {"a": t["a"]}
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_manager_keep_k_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    mgr.wait()
    mgr._gc()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]
    restored = mgr.restore(t)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_elastic_restore_new_sharding(tmp_path):
    """Restore applies target shardings (single device: placement noop,
    structure exercised; the 8-device elastic path runs in
    test_distributed_8dev.py)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), t)
    restored = restore_checkpoint(str(tmp_path), 5, t, shardings=sh)
    assert restored["a"].sharding.is_equivalent_to(NamedSharding(mesh, P()), 2)
