"""Vectorized TwoLevelHash.add_batch vs the per-row oracle (Alg. 6)."""

import numpy as np
import pytest

from repro.core import bitset
from repro.core.hashindex import TwoLevelHash, batch_heads


def _random_rows(rng, B, m, density=0.3, dup_frac=0.5):
    """Batch with heavy intra-batch duplication (the MR+ reduce shape)."""
    base = bitset.pack_bool(rng.random((max(1, B // 2), m)) < density)
    idx = rng.integers(0, base.shape[0], size=B)
    rows = base[idx].copy()
    # sprinkle fresh uniques
    fresh = rng.random(B) > dup_frac
    rows[fresh] = bitset.pack_bool(rng.random((int(fresh.sum()), m)) < density)
    return rows


@pytest.mark.parametrize("m", [1, 7, 32, 33, 125, 294])
def test_batch_heads_matches_scalar(m):
    rng = np.random.default_rng(m)
    rows = bitset.pack_bool(rng.random((64, m)) < 0.15)
    rows[0] = 0  # empty set → head -1
    heads = batch_heads(rows)
    for i in range(rows.shape[0]):
        assert heads[i] == bitset.head_attr(rows[i]), i


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("m", [5, 31, 64, 133])
def test_add_batch_matches_per_row_oracle(seed, m):
    rng = np.random.default_rng([seed, m])
    batches = [_random_rows(rng, int(rng.integers(1, 80)), m) for _ in range(4)]

    fast, oracle = TwoLevelHash(), TwoLevelHash()
    for rows in batches:
        got = fast.add_batch(rows)
        want = [i for i in range(rows.shape[0]) if oracle.add(rows[i])]
        assert got == want
        assert len(fast) == len(oracle)
        assert fast.bucket_stats() == oracle.bucket_stats()


def test_add_batch_first_occurrence_wins():
    H = TwoLevelHash()
    a = bitset.from_indices({1, 3}, 8)
    b = bitset.from_indices({2}, 8)
    rows = np.stack([a, b, a, b, a])
    assert H.add_batch(rows) == [0, 1]
    assert H.add_batch(rows) == []
    assert len(H) == 2
    assert a in H and b in H


def test_add_batch_empty_and_zero_rows():
    H = TwoLevelHash()
    assert H.add_batch(np.zeros((0, 2), np.uint32)) == []
    zero = np.zeros((3, 2), np.uint32)  # empty intent: head -1 bucket
    assert H.add_batch(zero) == [0]
    assert len(H) == 1


def test_add_and_add_batch_interoperate():
    rng = np.random.default_rng(0)
    rows = _random_rows(rng, 40, 20)
    H = TwoLevelHash()
    H.add(rows[7])
    got = H.add_batch(rows)
    assert 7 not in got
    # every row now present either way
    for i in range(rows.shape[0]):
        assert rows[i] in H
