"""Serving under load — admission queue semantics (deadline-or-full,
bounded-depth shed, bit-identity with pre-formed batches), arrival
processes, the open-loop driver on a virtual clock, snapshot swaps
racing dispatches (no dropped/double-counted latency observations),
OpenMetrics round-trips, and the SLO evaluation + regression gate."""

import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.core import all_closures_batched, bitset
from repro.core.context import FormalContext
from repro.dist.shardplan import ShardPlan
from repro.obs import (
    Registry,
    Tracer,
    parse_openmetrics,
    sanitize_name,
    span_rollup,
    to_openmetrics,
    use_tracer,
)
from repro.obs.export import MetricsServer
from repro.obs.slo import (
    SLO,
    burn_rate,
    check_baselines,
    evaluate,
    run_gate,
)
from repro.query import ConceptStore, QueryEngine, StreamUpdater
from repro.query.engine import QueryConfig
from repro.serve import (
    AdmissionConfig,
    AdmissionQueue,
    burst_arrivals,
    make_workload,
    poisson_arrivals,
    run_load,
)

SLOTS = 8


@pytest.fixture(scope="module")
def ctx():
    return FormalContext.synthetic(60, 18, 0.3, seed=5)


@pytest.fixture(scope="module")
def served(ctx):
    intents = all_closures_batched(ctx)
    plan = ShardPlan.simulated(2, block_n=16)
    store = ConceptStore.build(ctx, intents, plan=plan)
    return store, QueryEngine(store, QueryConfig(slots=SLOTS))


def _queries(ctx, n, seed=0):
    rng = np.random.default_rng(seed)
    base = ctx.rows[rng.integers(0, ctx.n_objects, size=n)]
    keep = bitset.pack_bool(rng.random((n, ctx.n_attrs)) < 0.25, ctx.W)
    return base & keep


# -- admission semantics -----------------------------------------------------


def test_queue_results_bit_identical_to_preformed_batch(served):
    _, qe = served
    qs = _queries(qe.store.ctx, 3 * SLOTS + 2, seed=1)
    queue = AdmissionQueue(qe, AdmissionConfig(max_wait_s=10.0))
    tickets = [queue.submit("closure", q) for q in qs]
    queue.flush()
    closures, supports, ids = qe.closure_batch(qs)
    for t, ec, es, ei in zip(tickets, closures, supports, ids):
        tc, ts, ti = t.result
        assert np.array_equal(np.asarray(tc), np.asarray(ec))
        assert int(ts) == int(es) and int(ti) == int(ei)


def test_full_batch_dispatches_inline_before_deadline(served):
    _, qe = served
    qs = _queries(qe.store.ctx, SLOTS, seed=2)
    queue = AdmissionQueue(qe, AdmissionConfig(max_wait_s=60.0))
    tickets = [queue.submit("closure", q) for q in qs]
    # the slots-th submit fills the batch: dispatched without any poll
    assert all(t.done for t in tickets)
    assert queue.stats.dispatch_causes == {"full": 1}
    assert queue.stats.occupancy_mean == 1.0
    assert queue.pending() == 0


def test_deadline_fires_partial_batch_on_fake_clock(served):
    _, qe = served
    t = [0.0]
    queue = AdmissionQueue(
        qe, AdmissionConfig(max_wait_s=1.0), clock=lambda: t[0]
    )
    qs = _queries(qe.store.ctx, 3, seed=3)
    tickets = [queue.submit("closure", q) for q in qs]
    assert queue.poll() == 0  # not due, not full
    assert not any(t_.done for t_ in tickets)
    assert queue.next_deadline_in() == pytest.approx(1.0)
    t[0] = 1.5  # oldest ticket aged past max_wait_s
    assert queue.poll() == 1
    assert all(t_.done for t_ in tickets)
    assert queue.stats.dispatch_causes == {"deadline": 1}
    assert queue.stats.occupancy_mean == pytest.approx(3 / SLOTS)
    # e2e on the fake clock: dispatched at 1.5, arrived at 0
    assert tickets[0].e2e_s == pytest.approx(1.5)


def test_bounded_depth_sheds_at_submit(served):
    _, qe = served
    depth = 5
    queue = AdmissionQueue(
        qe,
        AdmissionConfig(max_wait_s=60.0, depth=depth),
        clock=lambda: 0.0,
    )
    # depth < slots so nothing dispatches; overflow must shed
    qs = _queries(qe.store.ctx, depth + 3, seed=4)
    tickets = [queue.submit("closure", q) for q in qs]
    st = queue.stats
    assert [t.shed for t in tickets] == [False] * depth + [True] * 3
    assert st.submitted == depth + 3 and st.shed == 3
    assert st.admitted == depth
    assert st.shed_rate == pytest.approx(3 / (depth + 3))
    assert all(t.result is None and t.done for t in tickets[depth:])
    snap = queue.registry.export()
    assert snap["serve_shed_total{kind=closure}"] == 3
    assert snap["serve_queue_depth{kind=closure}"] == depth
    queue.flush()
    assert st.completed == depth  # shed tickets never reach the engine


def test_rules_kind_requires_index_and_unknown_kind_rejected(served):
    _, qe = served
    queue = AdmissionQueue(qe)
    with pytest.raises(ValueError, match="rules_index"):
        queue.submit("rules", _queries(qe.store.ctx, 1)[0])
    with pytest.raises(ValueError, match="unknown kind"):
        queue.submit("update", _queries(qe.store.ctx, 1)[0])


def test_dispatch_emits_span_and_registry_series(served):
    _, qe = served
    tr = Tracer()
    with use_tracer(tr):
        queue = AdmissionQueue(qe, AdmissionConfig(max_wait_s=10.0))
        # the engine registry is shared across this module's queues (one
        # /metrics snapshot covers queue + engine), so assert deltas
        before = queue.registry.export()
        for q in _queries(qe.store.ctx, SLOTS + 2, seed=5):
            queue.submit("closure", q)
        queue.flush()
    roll = span_rollup(tr.to_dict()["traceEvents"])
    assert roll["serve/dispatch"]["count"] == 2  # one full + one flush
    b = next(e for e in tr.to_dict()["traceEvents"]
             if e["ph"] == "B" and e["name"] == "serve/dispatch")
    assert b["args"]["kind"] == "closure" and b["args"]["n"] == SLOTS
    snap = queue.registry.export()

    def delta(key):
        now = snap.get(key, 0)
        was = before.get(key, 0)
        if isinstance(now, dict):
            return now["count"] - (was["count"] if isinstance(was, dict) else 0)
        return now - was

    assert delta("serve_submitted_total{kind=closure}") == SLOTS + 2
    assert delta("serve_dispatch_total{cause=full,kind=closure}") == 1
    assert delta("serve_dispatch_total{cause=flush,kind=closure}") == 1
    assert delta("serve_e2e_s{kind=closure}") == SLOTS + 2
    assert delta("serve_slot_occupancy") == 2


# -- arrival processes + workload mix ----------------------------------------


def test_poisson_arrivals_hit_target_rate():
    rng = np.random.default_rng(7)
    a = poisson_arrivals(200.0, 5.0, rng)
    assert a.size == pytest.approx(1000, rel=0.15)
    assert np.all(np.diff(a) >= 0) and a[-1] < 5.0
    gaps = np.diff(a)
    assert gaps.mean() == pytest.approx(1 / 200.0, rel=0.15)
    assert poisson_arrivals(0.0, 5.0, rng).size == 0


def test_burst_arrivals_keep_mean_and_show_the_factor():
    rng = np.random.default_rng(8)
    a = burst_arrivals(200.0, 20.0, rng, period_s=1.0, duty=0.25, factor=4.0)
    assert a.size == pytest.approx(4000, rel=0.15)  # mean rate preserved
    phase = (a / 1.0) % 1.0
    in_burst = (phase < 0.25).sum()
    # per-second rate ratio between the duty window and the rest ≈ factor
    ratio = (in_burst / 0.25) / ((a.size - in_burst) / 0.75)
    assert 2.5 < ratio < 6.0
    with pytest.raises(ValueError, match="factor"):
        burst_arrivals(200.0, 1.0, rng, factor=0.5)


def test_make_workload_mix_payloads_and_validation(ctx):
    rng = np.random.default_rng(9)
    events = make_workload(
        ctx, 400, rng, mix={"closure": 0.5, "lookup": 0.3, "update": 0.2}
    )
    assert len(events) == 400
    counts = {}
    for kind, payload in events:
        counts[kind] = counts.get(kind, 0) + 1
        if kind == "update":
            assert payload.shape == (2, ctx.W)
        else:
            assert payload.shape == (ctx.W,)
            # thinned real rows: subsets of context attribute space
            assert not np.any(payload & ~ctx.attr_mask())
    assert counts["closure"] == pytest.approx(200, rel=0.25)
    assert counts["update"] == pytest.approx(80, rel=0.35)
    with pytest.raises(ValueError, match="unknown workload kinds"):
        make_workload(ctx, 4, rng, mix={"extent": 1.0})
    with pytest.raises(ValueError, match="sum > 0"):
        make_workload(ctx, 4, rng, mix={"closure": 0.0})


# -- open-loop driver on a virtual clock -------------------------------------


def test_run_load_virtual_clock_accounting(served):
    _, qe = served
    t = [100.0]  # virtual seconds; sleep() advances it
    clock = lambda: t[0]  # noqa: E731
    sleep = lambda s: t.__setitem__(0, t[0] + s)  # noqa: E731
    queue = AdmissionQueue(
        qe, AdmissionConfig(max_wait_s=0.01), clock=clock
    )
    rng = np.random.default_rng(10)
    arrivals = poisson_arrivals(300.0, 1.0, rng)
    events = make_workload(
        qe.store.ctx, len(arrivals), rng, mix={"closure": 0.7, "lookup": 0.3}
    )
    rep = run_load(queue, arrivals, events, clock=clock, sleep=sleep)
    assert rep.submitted == len(arrivals)
    assert rep.admitted == rep.submitted  # depth 512 ≫ offered
    assert rep.completed == rep.admitted
    assert rep.shed == 0 and rep.shed_rate == 0.0
    assert rep.dispatches == sum(rep.dispatch_causes.values())
    assert rep.e2e["count"] == rep.completed
    assert rep.admission_wait["count"] == rep.completed
    # on the virtual clock queueing delay is bounded by the deadline
    # (dispatch itself costs zero virtual time)
    assert rep.e2e["max"] <= 0.01 + 1e-6
    assert rep.updates == 0 and rep.update_latency == {}
    d = rep.describe()
    json.dumps(d)
    assert d["shed_rate"] == 0.0
    assert rep.offered_qps == pytest.approx(
        len(arrivals) / float(arrivals[-1])
    )


def test_run_load_slo_and_backdated_arrivals(served):
    _, qe = served
    t = [0.0]
    clock = lambda: t[0]  # noqa: E731
    sleep = lambda s: t.__setitem__(0, t[0] + s)  # noqa: E731
    queue = AdmissionQueue(
        qe, AdmissionConfig(max_wait_s=0.005), clock=clock
    )
    arrivals = np.array([0.0, 0.001, 0.002, 0.5])
    events = [("closure", q) for q in _queries(qe.store.ctx, 4, seed=11)]
    rep = run_load(
        queue, arrivals, events, clock=clock, sleep=sleep,
        slo=SLO(latency_objective_s=0.25, max_shed_rate=0.0),
    )
    assert rep.slo["ok"] is True
    assert rep.slo["latency_ok"] and rep.slo["shed_ok"]
    assert rep.slo["burn_rate"] == 0.0
    # arrival_s was backdated to the schedule: tickets keep offered time
    assert rep.max_lag_s >= 0.0
    with pytest.raises(ValueError, match="one arrival time per event"):
        run_load(queue, arrivals[:2], events, clock=clock, sleep=sleep)


# -- satellite 3: snapshot swaps racing dispatches ---------------------------


def test_concurrent_commits_never_drop_or_double_count_latency(ctx):
    """A StreamUpdater commit swaps the snapshot while the queue is
    dispatching micro-batches from another thread.  Every admitted
    ticket must complete exactly once, and the latency histograms must
    hold exactly one observation per completion — a snapshot swap
    mid-micro-batch may reorder work but never lose or duplicate a
    measurement."""
    intents = all_closures_batched(ctx)
    plan = ShardPlan.simulated(2, block_n=16)
    store = ConceptStore.build(ctx, intents, plan=plan)  # local: commits mutate
    qe = QueryEngine(store, QueryConfig(slots=4))
    queue = AdmissionQueue(qe, AdmissionConfig(max_wait_s=0.0005))
    updater = StreamUpdater(store)
    v0 = store.snapshot.version

    n_commits = 4
    errs = []

    def churn():
        rng = np.random.default_rng(13)
        try:
            for _ in range(n_commits):
                rows = bitset.pack_bool(
                    rng.random((2, ctx.n_attrs)) < 0.3, ctx.W
                )
                updater.apply(rows)
        except Exception as e:  # surfaces in the main thread's assert
            errs.append(e)

    th = threading.Thread(target=churn)
    th.start()
    n = 64
    tickets = [
        queue.submit("closure", q) for q in _queries(ctx, n, seed=14)
    ]
    while queue.pending():
        queue.poll()
    queue.flush()
    th.join(timeout=60)
    assert not th.is_alive() and not errs, errs

    st = queue.stats
    assert st.admitted == n and st.shed == 0
    assert st.completed == n  # nothing dropped, nothing run twice
    assert all(t.done and t.result is not None for t in tickets)
    # exactly one latency observation per completion, in both ledgers
    assert st.registry.histogram("latency_s", kind="e2e").count == n
    assert st.registry.histogram("latency_s", kind="admission_wait").count == n
    assert queue.registry.histogram("serve_e2e_s", kind="closure").count == n
    assert store.snapshot.version == v0 + n_commits
    # post-churn queries serve from the committed snapshot, bit-identical
    # to a pre-formed batch against it
    qs = _queries(ctx, 4, seed=15)
    t2 = [queue.submit("closure", q) for q in qs]
    queue.flush()
    closures, supports, ids = qe.closure_batch(qs)
    for t_, ec, es in zip(t2, closures, supports):
        assert np.array_equal(np.asarray(t_.result[0]), np.asarray(ec))
        assert int(t_.result[1]) == int(es)


# -- OpenMetrics export ------------------------------------------------------


def _loaded_registry():
    r = Registry()
    r.counter("serve_shed_total", 3, kind="closure")
    r.counter("serve_shed_total", 1, kind="topk")
    r.gauge("serve_queue_depth", 7, kind="closure")
    r.observe("serve_e2e_s", 0.004, kind="closure")
    r.observe("serve_e2e_s", 0.020, kind="closure")
    r.observe("serve_e2e_s", 5e-7, kind="closure")  # underflow bucket
    return r


def test_openmetrics_renders_and_round_trips():
    text = to_openmetrics(_loaded_registry())
    fams = parse_openmetrics(text)  # strict validator — raises on drift
    assert fams["serve_shed"]["type"] == "counter"
    shed = {
        tuple(sorted(lbl.items())): v
        for _, lbl, v in fams["serve_shed"]["samples"]
    }
    assert shed[(("kind", "closure"),)] == 3.0
    # _s convention renders as _seconds with a UNIT line
    assert "# TYPE serve_e2e_seconds histogram" in text
    assert "# UNIT serve_e2e_seconds seconds" in text
    h = fams["serve_e2e_seconds"]
    assert h["type"] == "histogram"
    by_name = {}
    for name, lbl, v in h["samples"]:
        by_name.setdefault(name, []).append((lbl, v))
    (_, count), = by_name["serve_e2e_seconds_count"]
    assert count == 3.0
    inf_bucket = [
        v for lbl, v in by_name["serve_e2e_seconds_bucket"]
        if lbl["le"] == "+Inf"
    ]
    assert inf_bucket == [3.0]
    # the sub-µs observation lands in the explicit 1e-06 underflow bucket
    first = min(
        (float(lbl["le"]), v)
        for lbl, v in by_name["serve_e2e_seconds_bucket"]
    )
    assert first == (1e-6, 1.0)


def test_openmetrics_parser_rejects_malformed():
    with pytest.raises(ValueError, match="EOF"):
        parse_openmetrics("# TYPE x counter\nx_total 1\n")
    with pytest.raises(ValueError, match="no TYPE-declared"):
        parse_openmetrics("stray_metric 1\n# EOF\n")
    bad_hist = (
        "# TYPE h histogram\n"
        'h_bucket{le="0.1"} 5\n'
        'h_bucket{le="+Inf"} 3\n'  # cumulative counts must not decrease
        "h_count 3\nh_sum 1\n# EOF\n"
    )
    with pytest.raises(ValueError, match="cumulative"):
        parse_openmetrics(bad_hist)
    with pytest.raises(ValueError, match="re-declared"):
        parse_openmetrics("# TYPE x counter\n# TYPE x counter\n# EOF\n")
    assert sanitize_name("serve_e2e_s") == "serve_e2e_seconds"
    assert sanitize_name("bad name!") == "bad_name_"


def test_metrics_server_serves_live_snapshot():
    r = _loaded_registry()
    with MetricsServer(lambda: r, port=0) as srv:
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.status == 200
            assert "openmetrics-text" in resp.headers["Content-Type"]
            fams = parse_openmetrics(resp.read().decode())
        assert "serve_queue_depth" in fams
        r.counter("serve_shed_total", 10, kind="closure")  # live mutation
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            fams2 = parse_openmetrics(resp.read().decode())
        shed = {
            tuple(sorted(lbl.items())): v
            for _, lbl, v in fams2["serve_shed"]["samples"]
        }
        assert shed[(("kind", "closure"),)] == 13.0  # per-scrape provider
        bad = urllib.request.Request(srv.url.replace("/metrics", "/other"))
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(bad, timeout=10)


# -- SLO evaluation + regression gate ----------------------------------------


def test_burn_rate_budget_semantics():
    assert burn_rate(1.0, 0.995) == 0.0
    assert burn_rate(0.99, 0.995) == pytest.approx(2.0)
    assert burn_rate(0.995, 0.995) == pytest.approx(1.0)
    assert burn_rate(1.0, 1.0) == 0.0
    assert burn_rate(0.9, 1.0) == float("inf")


def test_evaluate_verdicts():
    slo = SLO(latency_objective_s=0.1, latency_target=0.99,
              max_shed_rate=0.01, max_p99_s=0.2)
    good = evaluate(slo, compliance=0.999, shed_rate=0.0, p99_s=0.05)
    assert good["ok"] and good["latency_ok"] and good["p99_ok"]
    assert good["burn_rate"] == pytest.approx(0.1)
    bad = evaluate(slo, compliance=0.9, shed_rate=0.05, p99_s=0.5)
    assert not bad["ok"]
    assert not bad["latency_ok"] and not bad["shed_ok"] and not bad["p99_ok"]
    assert bad["burn_rate"] == pytest.approx(10.0)


def test_check_baselines_tolerance_classes():
    artifact = {"headline": {
        "p99": 0.010, "shed_rate": 0.005, "bit_identical": True,
    }}
    baseline = {
        "latency_s": {"headline.p99": 0.004},
        "rate": {"headline.shed_rate": 0.0},
        "exact": {"headline.bit_identical": True},
    }
    # 0.010 < 0.004×4 ceiling, 0.005 < 0+0.02 slack, invariant holds
    assert check_baselines(artifact, baseline) == []
    artifact["headline"]["p99"] = 0.040  # 10× the baseline: trips the gate
    v = check_baselines(artifact, baseline)
    assert len(v) == 1 and "latency regression" in v[0]
    artifact["headline"]["shed_rate"] = 0.5
    artifact["headline"]["bit_identical"] = False
    v = check_baselines(artifact, baseline)
    assert len(v) == 3
    assert any("rate regression" in s for s in v)
    assert any("invariant broken" in s for s in v)
    # a missing metric path is a violation, not a silent skip
    v = check_baselines({"headline": {}}, baseline)
    assert len(v) == 3 and all("no " in s for s in v)


def test_run_gate_green_then_red_on_injected_regression(tmp_path):
    import io

    artifact = {"headline": {"p99": 0.010, "bit_identical": True}}
    baselines = {
        "tolerance_ratio": 4.0,
        "artifacts": {"BENCH_x.json": {
            "latency_s": {"headline.p99": 0.008},
            "exact": {"headline.bit_identical": True},
        }},
    }
    art = tmp_path / "BENCH_x.json"
    base = tmp_path / "slo_baselines.json"
    art.write_text(json.dumps(artifact))
    base.write_text(json.dumps(baselines))
    out = io.StringIO()
    assert run_gate([str(art)], str(base), out=out) == 0
    assert "OK" in out.getvalue()
    # inject a 10× latency regression → the gate must go red
    artifact["headline"]["p99"] = 0.10
    art.write_text(json.dumps(artifact))
    out = io.StringIO()
    assert run_gate([str(art)], str(base), out=out) == 1
    assert "latency regression" in out.getvalue()
    # unknown artifact (no baseline entry) is red, not silently skipped
    other = tmp_path / "BENCH_unknown.json"
    other.write_text("{}")
    assert run_gate([str(other)], str(base), out=io.StringIO()) == 1
